"""Flash attention Pallas kernel (TPU target; hymba/granite prefill+train
hot spot — see EXPERIMENTS.md §Perf).

Rationale from the dry-run byte attribution: the pure-XLA chunked
attention still writes/reads the [B, H, c, T] score chain through HBM
(~40% of hymba train_4k's memory term).  The flash formulation keeps
score tiles in VMEM — HBM traffic reduces to Q/K/V/O — which is the
classic reason this kernel exists on TPU.

Layout: q [B, H, Tq, d], k/v [B, H, Tk, d] (GQA callers repeat or reshape
heads).  Grid (B*H, Tq/bq); the kernel loops KV blocks with the online
max/sum recurrence, f32 accumulators in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  bq: int, bk: int, kv_len: int, causal: bool,
                  scale: float):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale          # [bq, d]
    n_kv = kv_len // bk

    m_ref[...] = jnp.full_like(m_ref, NEG)
    l_ref[...] = jnp.zeros_like(l_ref)
    acc_ref[...] = jnp.zeros_like(acc_ref)

    def body(j, _):
        k_blk = pl.load(k_ref, (0, pl.ds(j * bk, bk),
                                slice(None))).astype(jnp.float32)
        v_blk = pl.load(v_ref, (0, pl.ds(j * bk, bk),
                                slice(None))).astype(jnp.float32)
        s = jnp.dot(q, k_blk.T,
                    preferred_element_type=jnp.float32)   # [bq, bk]
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 0)
            kpos = j * bk + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1,
                                                  keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v_blk, preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        return 0

    # causal: skip kv blocks strictly after this q block
    upper = n_kv if not causal else \
        jnp.minimum(n_kv, (qi + 1) * bq // bk + (1 if bq % bk else 0))
    upper = jnp.maximum(upper, 1)
    jax.lax.fori_loop(0, upper, body, 0)
    o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bq", "bk", "causal", "interpret", "out_dtype"))
def flash_attention(
    q: jnp.ndarray,          # [BH, Tq, d]
    k: jnp.ndarray,          # [BH, Tk, d]
    v: jnp.ndarray,          # [BH, Tk, d]
    *,
    bq: int = 128,
    bk: int = 128,
    causal: bool = True,
    interpret: bool = False,
    out_dtype=None,
) -> jnp.ndarray:
    bh, tq, d = q.shape
    _, tk, _ = k.shape
    assert tq % bq == 0 and tk % bk == 0, (q.shape, k.shape, bq, bk)
    out_dtype = out_dtype or q.dtype
    grid = (bh, tq // bq)
    return pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bk=bk, kv_len=tk,
                          causal=causal, scale=d ** -0.5),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, tk, d), lambda h, i: (h, 0, 0)),
            pl.BlockSpec((1, tk, d), lambda h, i: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, tq, d), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
