"""repro.runtime — the traffic layer above :mod:`repro.engine`.

PR 1 built the single-request engine (one overlay, one binary pass per
request).  This package turns it into a production-shaped serving
runtime, the host-scale projection of the paper's Algorithm 9:

  * :class:`Batcher` — dynamic batching: coalesce concurrent requests
    that share a (model schema, graph signature) cache key into one
    padded/stacked feature tensor, flushed on ``max_batch`` or
    ``max_wait_us``; one batch = ONE binary pass.
  * :class:`OverlayPool` — K virtual overlays (one fixed tile geometry
    each) with cache-affinity routing: a key goes to the overlay that
    already compiled its program, else to the least-loaded overlay via
    the compiler's own LPT greedy (the idle-PE rule).
  * :class:`ServeLoop` — the bounded work queue: admission control /
    backpressure (:class:`QueueFullError`), deterministic drain order,
    and compile/execute overlap across overlays.
  * :class:`Metrics` — per-key and global telemetry (p50/p99 latency,
    throughput, queue depth, batch occupancy, program-cache hit rate)
    exported as a JSON-serializable snapshot.

The per-user request layer sits one level above: :mod:`repro.sampling`
turns "label these vertices" traffic into bucketed, graph-as-data
requests whose cache keys collide per geometry bucket — exactly the
same-key grouping the :class:`Batcher` coalesces — so sampled
ego-network serving rides this runtime unchanged
(:class:`repro.sampling.SamplingService` wraps an :class:`OverlayPool`).

Quickstart::

    from repro.runtime import OverlayPool

    pool = OverlayPool(n_overlays=2, geometry=geom)
    responses = pool.serve(requests, max_batch=8, max_wait_us=2000)
    print(pool.metrics.snapshot(max_batch=8))
"""
from .batcher import Batch, Batcher, request_cost
from .metrics import Metrics, percentile
from .pool import OverlayPool, warm_pool
from .serve_loop import QueueFullError, ServeLoop

__all__ = [
    "Batch", "Batcher", "Metrics", "OverlayPool", "QueueFullError",
    "ServeLoop", "percentile", "request_cost", "warm_pool",
]
