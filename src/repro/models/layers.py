"""Shared neural layers: norms, RoPE, MLP, initializers.

Pure-function style: params are dict pytrees; init functions take an
``jax.random`` key; every apply function is shape-polymorphic over leading
batch dims.  f32 accumulation for norms/softmax; storage dtype from config.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def _normal(key, shape, std, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def dense_init(key, d_in: int, d_out, dtype, std: Optional[float] = None):
    shape = (d_in,) + ((d_out,) if isinstance(d_out, int) else tuple(d_out))
    std = std if std is not None else d_in ** -0.5
    return _normal(key, shape, std, dtype)


# --------------------------------------------------------------------------- #
def rms_norm(x: jnp.ndarray, scale: jnp.ndarray,
             eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------------- #
def rope(x: jnp.ndarray, positions: jnp.ndarray,
         theta: float = 10000.0) -> jnp.ndarray:
    """Rotary embedding.  x: [..., T, H, D]; positions: [..., T]."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None, None].astype(jnp.float32) * freq
    sin, cos = jnp.sin(ang), jnp.cos(ang)          # [..., T, 1, half]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1.astype(jnp.float32) * cos - x2.astype(jnp.float32) * sin
    y2 = x2.astype(jnp.float32) * cos + x1.astype(jnp.float32) * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------- #
def swiglu_init(key, d: int, f: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": dense_init(k1, d, f, dtype),
        "wg": dense_init(k2, d, f, dtype),
        "wo": dense_init(k3, f, d, dtype),
    }


def swiglu(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    h = jnp.einsum("...d,df->...f", x, p["wi"])
    g = jnp.einsum("...d,df->...f", x, p["wg"])
    h = h * jax.nn.sigmoid(g.astype(jnp.float32)).astype(h.dtype)
    return jnp.einsum("...f,fd->...d", h, p["wo"])


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean token cross-entropy; f32 logsumexp."""
    lg = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lg, axis=-1)
    ll = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)
