"""Pure-jnp reference execution of a ModelIR on a graph.

This is (1) the correctness oracle for the compiled overlay executor and
(2) the stand-in for the framework baseline (PyG/DGL-style whole-graph
execution) in the benchmarks: every layer materializes full |V|xF
intermediates with no partitioning, fusion, or reordering.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .graph import Graph
from .ir import Activation, AggOp, LayerIR, LayerType, ModelIR


def apply_activation(x: jnp.ndarray, act: Activation) -> jnp.ndarray:
    if act == Activation.NONE:
        return x
    if act == Activation.RELU:
        return jax.nn.relu(x)
    if act == Activation.LRELU:
        return jax.nn.leaky_relu(x, 0.2)
    if act == Activation.PRELU:
        return jnp.where(x >= 0, x, 0.25 * x)
    if act in (Activation.SWISH, Activation.SILU):
        return x * jax.nn.sigmoid(x)
    if act == Activation.EXP:
        return jnp.exp(x)
    if act == Activation.SIGMOID:
        return jax.nn.sigmoid(x)
    if act == Activation.GELU:
        return jax.nn.gelu(x)
    raise ValueError(f"activation {act} must be handled by caller")


def edge_softmax(ew: jnp.ndarray, dst: jnp.ndarray, n: int) -> jnp.ndarray:
    """Softmax of edge scores over incoming edges of each destination."""
    mx = jax.ops.segment_max(ew, dst, num_segments=n)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    ex = jnp.exp(ew - mx[dst])
    den = jax.ops.segment_sum(ex, dst, num_segments=n)
    return ex / jnp.maximum(den[dst], 1e-12)


def aggregate(
    x: jnp.ndarray, g: Graph, op: AggOp, edge_w: Optional[jnp.ndarray]
) -> jnp.ndarray:
    """out[dst] = AggOp_{e=(src,dst)} (w_e * x[src])   (paper Eq. 5)."""
    src = jnp.asarray(g.src)
    dst = jnp.asarray(g.dst)
    w = jnp.asarray(g.weight) if edge_w is None else edge_w
    msg = x[src] * w[:, None]
    n = g.n_vertices
    if op == AggOp.SUM:
        return jax.ops.segment_sum(msg, dst, num_segments=n)
    if op == AggOp.MEAN:
        s = jax.ops.segment_sum(msg, dst, num_segments=n)
        deg = jax.ops.segment_sum(jnp.ones_like(w), dst, num_segments=n)
        return s / jnp.maximum(deg, 1.0)[:, None]
    if op == AggOp.MAX:
        m = jax.ops.segment_max(msg, dst, num_segments=n)
        return jnp.where(jnp.isfinite(m), m, 0.0)
    if op == AggOp.MIN:
        m = jax.ops.segment_min(msg, dst, num_segments=n)
        return jnp.where(jnp.isfinite(m), m, 0.0)
    raise ValueError(op)


def run_reference(
    model: ModelIR, g: Graph, x: jnp.ndarray,
    weights: Optional[Dict[str, np.ndarray]] = None,
) -> jnp.ndarray:
    """Execute the IR layer by layer; returns the final layer's output."""
    weights = weights if weights is not None else model.weights
    vals: Dict[int, jnp.ndarray] = {}

    def inp(lid_or_input: int) -> jnp.ndarray:
        return x if lid_or_input == -1 else vals[lid_or_input]

    out_id = None
    for lid in model.topo_order():
        l: LayerIR = model.layers[lid]
        feat_parents = [p for p in l.parent_ids
                        if p != l.attrs.get("edge_weight_layer")]
        h = vals[feat_parents[0]] if feat_parents else x

        if l.layer_type == LayerType.AGGREGATE:
            ewl = l.attrs.get("edge_weight_layer")
            ew = vals[ewl] if ewl is not None else None
            y = aggregate(h, g, l.agg_op, ew)
        elif l.layer_type == LayerType.LINEAR:
            W = jnp.asarray(weights[l.attrs["W"]])
            y = h @ W
            if "b" in l.attrs:
                y = y + jnp.asarray(weights[l.attrs["b"]])
        elif l.layer_type == LayerType.VECTOR_INNER:
            src, dst = jnp.asarray(g.src), jnp.asarray(g.dst)
            if l.attrs.get("mode") == "pair_sum":
                y = h[src, 0] + h[dst, 1]
            else:
                y = jnp.sum(h[src] * h[dst], axis=-1)
        elif l.layer_type == LayerType.VECTOR_ADD:
            a, b = l.attrs["operands"]
            y = l.attrs["alpha"] * inp(a) + l.attrs["beta"] * inp(b)
        elif l.layer_type == LayerType.ACTIVATION:
            if l.act == Activation.EDGE_SOFTMAX:
                y = edge_softmax(h, jnp.asarray(g.dst), g.n_vertices)
            else:
                y = apply_activation(h, l.act)
        elif l.layer_type == LayerType.BATCHNORM:
            p = {k: jnp.asarray(weights[l.attrs[k]])
                 for k in ("mu", "sigma", "gamma", "beta")}
            eps = l.attrs.get("eps", 1e-5)
            y = (h - p["mu"]) / jnp.sqrt(p["sigma"] ** 2 + eps)
            y = y * p["gamma"] + p["beta"]
        else:
            raise ValueError(l.layer_type)

        # Fused epilogues (set by the fusion pass): scale/shift then act.
        if "fused_scale" in l.attrs:
            y = (y * jnp.asarray(weights[l.attrs["fused_scale"]])
                 + jnp.asarray(weights[l.attrs["fused_shift"]]))
        if "fused_act" in l.attrs:
            fa = Activation(l.attrs["fused_act"])
            if fa == Activation.EDGE_SOFTMAX:
                y = edge_softmax(y, jnp.asarray(g.dst), g.n_vertices)
            else:
                y = apply_activation(y, fa)
        vals[lid] = y
        out_id = lid
    # Output = last layer in topo order with no children.
    sinks = [i for i, l in model.layers.items() if not l.child_ids]
    return vals[sinks[-1]] if sinks else vals[out_id]
