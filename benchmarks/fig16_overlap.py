"""Paper Fig. 16: computation/communication overlap (double buffering).
Measured: async dispatch vs per-tile barriers; Derived: the analytic
model's max() vs sum() per tiling block (paper: 112-186% speedup)."""
from __future__ import annotations

from repro.core.perfmodel import predict_loh

from .common import (Engine, MODELS, dataset, emit, features, run_model)

GRAPHS = [("PU", 1.0)]


def run(quick: bool = False) -> None:
    graphs = GRAPHS[:1] if quick else GRAPHS
    models = ["b1", "b2"] if quick else MODELS
    eng_on = Engine(overlap=True)
    eng_off = Engine(overlap=False)
    for bname in models:
        for dname, scale in graphs:
            g = dataset(dname, scale)
            x = features(g)
            _, t_on, _, prog, _ = run_model(bname, g, x, eng_on)
            _, t_off, _, _, _ = run_model(bname, g, x, eng_off)
            p_on = predict_loh(prog.source.program, overlap=True)
            p_off = predict_loh(prog.source.program, overlap=False)
            label = dname if scale == 1.0 else f"{dname}@{scale:g}"
            emit([f"fig16,{bname}/{label},{t_on * 1e6:.0f},"
                  f"speedup={(t_off / t_on - 1) * 100:.1f}%;"
                  f"pred_speedup={(p_off / p_on - 1) * 100:.1f}%"])
