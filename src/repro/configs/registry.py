"""Registry of the assigned architectures (``--arch <id>``)."""
from __future__ import annotations

import importlib
from typing import List

from repro.models.config import ModelConfig

_MODULES = {
    "granite-8b": "granite_8b",
    "gemma3-12b": "gemma3_12b",
    "qwen3-0.6b": "qwen3_0_6b",
    "gemma3-27b": "gemma3_27b",
    "kimi-k2-1t-a32b": "kimi_k2",
    "deepseek-v3-671b": "deepseek_v3",
    "hymba-1.5b": "hymba_1_5b",
    "llama-3.2-vision-11b": "llama32_vision_11b",
    "whisper-base": "whisper_base",
    "xlstm-125m": "xlstm_125m",
}

ARCHS: List[str] = list(_MODULES)


def _mod(arch: str):
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _mod(arch).config()


def get_smoke_config(arch: str) -> ModelConfig:
    return _mod(arch).smoke_config()
