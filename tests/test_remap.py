"""Bind-time sparsity-adaptive kernel remapping (Dynasparse-style).

Covers the tentpole acceptance criteria:
  * forced-SpDMM remap restores the canonical binary BYTE for byte
    (the self-describing NOP/flags encoding round-trips), on b1-b8;
  * forced-GEMM remap executes bit-identically across the
    device-resident, host-streaming, and mesh paths, and matches the
    unremapped program within float-reassociation tolerance;
  * skip-empty elision (a live delta draining a tile) is BIT-identical
    to a cold compile of the mutated graph, while the program-cache
    key survives and ``ExecStats.tiles_skipped`` counts the elisions;
  * the livegraph rebind re-remaps ONLY delta-patched tiles — every
    other tile's words and record entries are preserved verbatim, and
    untouched tile objects stay COW-shared with the parent version;
  * ``repro.verify`` passes on remapped programs/bundles and fails on
    a tampered record (both directions: binary GEMM with a record
    claiming spdmm, and a smuggled GEMM with no record at all).
"""
import copy
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import graph as G
from repro.core.isa import HEADER_BYTES, Instr, Opcode, disassemble
from repro.core.passes.partition import PartitionConfig
from repro.core.passes.remap import (_scan_groups, remap_program,
                                     resolve_density)
from repro.engine import Engine
from repro.livegraph.delta import GraphDelta
from repro.livegraph.versioning import GraphVersionStore
from repro.verify.checks import verify_program

GEOM = PartitionConfig(n1=32, n2=8)
N_DEV = min(4, jax.local_device_count())


def _g(nv=90, ne=400, f=12, c=4, seed=0):
    g = G.random_graph(nv, ne, seed=seed).gcn_normalized()
    g.feat_dim, g.n_classes = f, c
    return g


def _engine(**kw) -> Engine:
    return Engine(geometry=GEOM, n_pes=4, **kw)


def _words(binary: bytes) -> np.ndarray:
    return np.frombuffer(binary, dtype="<u4",
                         offset=HEADER_BYTES).reshape(-1, 4)


# --------------------------------------------------------------------------- #
# Restore round-trip: the remapped encoding is self-describing.
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("name", ["b1", "b2", "b3", "b4", "b6", "b7"])
def test_forced_spdmm_restores_canonical(name):
    eng = _engine()
    prog = eng.compile(name, _g(seed=3))
    rp = eng.remap(prog, force="spdmm")
    assert rp.binary == prog.binary
    assert rp.manifest["remap"]["counts"]["gemm"] == 0
    assert rp.manifest["remap"]["counts"]["skip"] == 0


@pytest.mark.slow
@pytest.mark.parametrize("name", ["b5", "b8"])
def test_forced_spdmm_restores_canonical_deep(name):
    eng = _engine()
    prog = eng.compile(name, _g(seed=3))
    assert eng.remap(prog, force="spdmm").binary == prog.binary


@pytest.mark.parametrize("name", ["b1", "b3", "b6"])
def test_forced_gemm_roundtrips_through_restore(name):
    """remap(gemm) then remap(spdmm) on the REMAPPED program recovers
    the canonical bytes — restore works on non-canonical input, which
    is what makes incremental re-remapping a pure word edit."""
    eng = _engine()
    prog = eng.compile(name, _g(seed=3))
    rp = eng.remap(prog, force="gemm")
    assert rp.binary != prog.binary
    assert rp.manifest["remap"]["counts"]["gemm"] > 0
    back = remap_program(rp, force="spdmm")
    assert back.binary == prog.binary


# --------------------------------------------------------------------------- #
# Execution: forced-GEMM across all three residency paths.
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("name", ["b1", "b3", "b6"])
def test_forced_gemm_bit_identical_across_paths(name):
    g = _g(seed=21)
    x = jnp.asarray(G.random_features(g, seed=2))
    eng = _engine()
    prog = eng.compile(name, g, mesh=N_DEV)
    y0 = np.asarray(eng.run(prog, x))

    rp = eng.remap(prog, force="gemm")
    y_dev = np.asarray(eng.run(rp, x))
    assert eng.exec_stats.tiles_remapped > 0
    assert eng.exec_stats.tile_ops_by_mode.get("gemm", 0) > 0
    y_host = np.asarray(eng.run(rp, x, residency="host"))
    y_mesh = np.asarray(eng.run(rp, x, mesh=N_DEV))
    # dense-aggregate GEMM reassociates the per-edge sums: allclose vs
    # the sparse reference, but bit-exact across residency paths.
    assert np.allclose(y_dev, y0, rtol=1e-4, atol=1e-4)
    assert np.array_equal(y_dev, y_host)
    assert np.array_equal(y_dev, y_mesh)


def test_auto_remap_spdmm_skip_is_bit_identical():
    """Restricting modes to spdmm/skip makes auto remap a bit-exact
    transformation (skip only fires on truly empty tiles)."""
    g = _g(seed=7)
    x = jnp.asarray(G.random_features(g, seed=4))
    eng = _engine()
    prog = eng.compile("b1", g)
    y0 = np.asarray(eng.run(prog, x))
    rp = eng.remap(prog, modes=("spdmm", "skip"))
    assert rp.manifest["remap"]["counts"]["gemm"] == 0
    assert np.array_equal(np.asarray(eng.run(rp, x)), y0)


def test_forced_gemm_honors_nonlinear_aggops():
    """A globally-gemm'd program keeps SPDMM encodings inside MAX/MIN
    aggregate layers — b3 (SAGE) carries a max-aggregate."""
    eng = _engine()
    prog = eng.compile("b3", _g(seed=3))
    rp = eng.remap(prog, force="gemm")
    instrs = disassemble(rp.binary)
    by_agg = {}
    for grp in _scan_groups(instrs):
        op = instrs[grp.compute].op
        by_agg.setdefault(int(grp.agg), set()).add(op)
    for agg, ops in by_agg.items():
        from repro.core.ir import AggOp
        if agg in (int(AggOp.SUM), int(AggOp.MEAN)):
            assert ops == {Opcode.GEMM}
        else:
            assert ops == {Opcode.SPDMM}


# --------------------------------------------------------------------------- #
# Skip-empty on a live graph; incremental rebind remap.
# --------------------------------------------------------------------------- #
def _drain_smallest_tile(store):
    jk = min(store.edges, key=lambda k: store.edges[k].n)
    te = store.edges[jk]
    d = GraphDelta(base_vertices=store.n_vertices)
    for u, w in zip(te.src.tolist(), te.dst.tolist()):
        d.remove_edge(u, w)
    return jk, d


def test_skip_empty_elision_bit_identical_to_cold():
    g = _g(seed=7)
    x = jnp.asarray(G.random_features(g, seed=4))
    live = GraphVersionStore(g, GEOM, name="lv")
    eng = _engine()
    prog = eng.compile("b1", live.head.as_graph())
    eng.remap(prog, modes=("spdmm", "skip"))   # re-caches remapped copy

    jk, d = _drain_smallest_tile(live.head.store)
    v1 = live.apply(d)
    assert not v1.stats.structural_change
    compiles = eng.stats.compiles
    p1 = eng.compile("b1", v1.as_graph())
    assert eng.stats.compiles == compiles       # content-only: cache hit
    rec = p1.manifest["remap"]
    assert rec["tiles"][f"{jk[0]}:{jk[1]}"]["mode"] == "skip"
    assert rec["counts"]["skip"] >= 1
    assert rec["skipped_tile_ops"] > 0

    y = np.asarray(eng.run(p1, x))
    assert eng.exec_stats.tiles_skipped == rec["skipped_tile_ops"]
    y_host = np.asarray(eng.run(p1, x, residency="host"))
    assert np.array_equal(y, y_host)

    g1 = d.apply_to(g)
    cold = _engine()
    y_cold = np.asarray(cold.run(cold.compile("b1", g1), x))
    assert np.array_equal(y, y_cold)


def test_rebind_remaps_only_patched_tiles():
    g = _g(seed=7)
    live = GraphVersionStore(g, GEOM, name="lv")
    eng = _engine()
    prog = eng.compile("b1", live.head.as_graph())
    rp0 = eng.remap(prog, force="gemm")

    jk_empty, d = _drain_smallest_tile(live.head.store)
    jk_other = max(live.head.store.edges,
                   key=lambda k: live.head.store.edges[k].n)
    o = live.head.store.edges[jk_other]
    d.add_edge(int(o.src[0]), int(o.dst[0]), 0.5)
    v1 = live.apply(d)
    patched = set(v1.stats.patched)
    assert patched == {f"{jk_empty[0]}:{jk_empty[1]}",
                       f"{jk_other[0]}:{jk_other[1]}"}

    p1 = eng.compile("b1", v1.as_graph())
    rec = p1.manifest["remap"]
    assert rec["tiles"][f"{jk_empty[0]}:{jk_empty[1]}"]["mode"] == "skip"
    # untouched tiles keep their forced-gemm record entries verbatim
    for jk, entry in rec["tiles"].items():
        if jk not in patched:
            assert entry == rp0.manifest["remap"]["tiles"][jk]

    # word-level: every differing instruction belongs to a patched tile
    w0, w1 = _words(rp0.binary), _words(p1.binary)
    assert w0.shape == w1.shape
    diff_rows = set(np.nonzero((w0 != w1).any(axis=1))[0].tolist())
    instrs = [Instr.decode(w) for w in w0]
    owner = {}
    for grp in _scan_groups(instrs):
        for idx in (grp.compute, *grp.mem):
            owner[idx] = f"{grp.j}:{grp.k}"
    for row in diff_rows:
        assert owner.get(row) in patched, \
            f"instr {row} changed outside the patched tiles"

    # COW: untouched tile objects are THE SAME as the parent's
    for jk in v1.store.tiles:
        if f"{jk[0]}:{jk[1]}" not in patched:
            assert v1.store.tiles[jk] is live.get(0).store.tiles[jk]

    # rebinding the same program again reuses the cached bound copy
    again = v1.bind(eng.cache.get(prog.cache_key))
    assert again is v1.bind(eng.cache.get(prog.cache_key))


# --------------------------------------------------------------------------- #
# Density sources.
# --------------------------------------------------------------------------- #
def test_exec_profile_density_source():
    g = _g(seed=7)
    x = jnp.asarray(G.random_features(g, seed=4))
    eng = _engine()
    prog = eng.compile("b1", g)
    with pytest.raises(ValueError):
        resolve_density(prog, "exec_profile")
    eng._executor.profile_tiles = True
    eng.run(prog, x)
    stats, src = resolve_density(prog, "exec_profile")
    assert src == "exec_profile"
    pg_nnz = {f"{j}:{k}": sum(t.nnz for t in ts)
              for (j, k), ts in prog.pgraph.tiles.items()}
    assert {jk: s["nnz"] for jk, s in stats.items()} == pg_nnz
    rp = eng.remap(prog, source="exec_profile")
    assert rp.manifest["remap"]["source"] == "exec_profile"


def test_calibrated_constants_change_signature():
    eng = _engine()
    prog = eng.compile("b1", _g(seed=3))
    r_default = eng.remap(prog)
    r_cal = eng.remap(prog, report={"peak_flops": 1e12, "vpu_flops": 1e9,
                                    "hbm_bw": 1e10})
    assert not r_default.manifest["remap"]["calibrated"]
    assert r_cal.manifest["remap"]["calibrated"]
    assert r_default.manifest["remap"]["signature"] != \
        r_cal.manifest["remap"]["signature"]


# --------------------------------------------------------------------------- #
# Verification: remapped programs pass; tampering fails.
# --------------------------------------------------------------------------- #
def test_verify_passes_on_remapped_gagi(tmp_path):
    g = _g(seed=3)
    eng = _engine()
    prog = eng.compile("b1", g, mesh=N_DEV)
    rp = eng.remap(prog, force="gemm")     # Engine.remap verifies too
    assert verify_program(rp).ok
    path = str(tmp_path / "remapped.gagi")
    rp.save(path)
    from repro.verify.checks import verify_gagi
    assert verify_gagi(path).ok


def test_verify_catches_tampered_record():
    eng = _engine()
    rp = eng.remap(eng.compile("b1", _g(seed=3)), force="gemm")
    bad = dataclasses.replace(rp, manifest=copy.deepcopy(rp.manifest))
    jk = next(k for k, e in bad.manifest["remap"]["tiles"].items()
              if e["mode"] == "gemm")
    bad.manifest["remap"]["tiles"][jk]["mode"] = "spdmm"
    rep = verify_program(bad)
    assert not rep.ok
    assert any("remap record marks it spdmm" in v.message
               for v in rep.violations)


def test_verify_catches_unrecorded_gemm():
    """A GEMM smuggled into an AGGREGATE layer with NO remap record
    still fails — the legality gate did not simply get wider."""
    eng = _engine()
    prog = eng.compile("b1", _g(seed=3))
    rp = remap_program(prog, force="gemm")
    stripped = dict(rp.manifest)
    del stripped["remap"]
    bad = dataclasses.replace(rp, manifest=stripped, _plan=None)
    rep = verify_program(bad)
    assert not rep.ok
    assert any("no remap record" in v.message for v in rep.violations)
