"""Builders: GNN model specs -> ModelIR (+ random weights).

Covers the paper's evaluated models (Table 5): GCN (b1/b2), GraphSAGE
(b3/b4), GIN (b5), GAT (b6), SGC (b7), and a GraphGym-style stack (b8).
Each builder mirrors how PyG would decompose the model into the six
computation-layer types of the IR (paper Fig. 10).
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from .graph import Graph
from .ir import Activation, AggOp, LayerIR, LayerType, ModelIR


def _rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


class _B:
    """Small helper to build linear chains/branches of LayerIRs."""

    def __init__(self, g: Graph, name: str, seed: int = 0) -> None:
        self.m = ModelIR()
        self.m.name = name
        self.m.graph_meta = {
            "n_vertices": g.n_vertices,
            "n_edges": g.n_edges,
            "feat_dim": g.feat_dim,
        }
        self.g = g
        self.rng = _rng(seed)

    def add(self, layer: LayerIR, parents: List[int]) -> int:
        layer.layer_id = self.m.next_id()
        layer.parent_ids = list(parents)
        layer.n_vertices = self.g.n_vertices
        layer.n_edges = self.g.n_edges
        self.m.add_layer(layer)
        for p in parents:
            self.m.layers[p].child_ids.append(layer.layer_id)
        return layer.layer_id

    # ------------------------------------------------------------------ #
    def linear(self, parent: Optional[int], f_in: int, f_out: int,
               bias: bool = True, tag: str = "") -> int:
        lid = self.m.next_id()
        wkey, bkey = f"L{lid}.W", f"L{lid}.b"
        self.m.weights[wkey] = (
            self.rng.normal(0, 1, (f_in, f_out)).astype(np.float32)
            / np.sqrt(f_in)
        )
        attrs = {"W": wkey}
        if bias:
            self.m.weights[bkey] = np.zeros((f_out,), np.float32)
            attrs["b"] = bkey
        if tag:
            attrs["tag"] = tag
        l = LayerIR(LayerType.LINEAR, 0, f_in=f_in, f_out=f_out, attrs=attrs)
        return self.add(l, [] if parent is None else [parent])

    def aggregate(self, parent: Optional[int], f: int, op: AggOp = AggOp.SUM,
                  edge_weight_layer: Optional[int] = None) -> int:
        attrs = {}
        if edge_weight_layer is not None:
            attrs["edge_weight_layer"] = edge_weight_layer
        l = LayerIR(LayerType.AGGREGATE, 0, f_in=f, f_out=f, agg_op=op,
                    attrs=attrs)
        parents = [] if parent is None else [parent]
        if edge_weight_layer is not None:
            parents = parents + [edge_weight_layer]
        return self.add(l, parents)

    def activation(self, parent: int, f: int, act: Activation,
                   on_edges: bool = False) -> int:
        l = LayerIR(LayerType.ACTIVATION, 0, f_in=f, f_out=f, act=act,
                    act_enabled=True, attrs={"on_edges": on_edges})
        return self.add(l, [parent])

    def batchnorm(self, parent: int, f: int) -> int:
        lid = self.m.next_id()
        for k, v in [("mu", self.rng.normal(0, 0.5, f)),
                     ("sigma", np.abs(self.rng.normal(1, 0.2, f)) + 0.5),
                     ("gamma", self.rng.normal(1, 0.2, f)),
                     ("beta", self.rng.normal(0, 0.2, f))]:
            self.m.weights[f"L{lid}.{k}"] = v.astype(np.float32)
        l = LayerIR(LayerType.BATCHNORM, 0, f_in=f, f_out=f,
                    batch_enabled=True,
                    attrs={"eps": 1e-5, **{k: f"L{lid}.{k}" for k in
                                           ("mu", "sigma", "gamma", "beta")}})
        return self.add(l, [parent])

    def vadd(self, pa: Optional[int], pb: Optional[int], f: int,
             alpha: float = 1.0, beta: float = 1.0) -> int:
        """out = alpha*X_a + beta*X_b.  A ``None`` operand reads the model
        input features; attrs['operands'] keeps the positional mapping
        (-1 == model input)."""
        l = LayerIR(LayerType.VECTOR_ADD, 0, f_in=f, f_out=f,
                    attrs={"alpha": alpha, "beta": beta,
                           "operands": [pa if pa is not None else -1,
                                        pb if pb is not None else -1]})
        parents = [p for p in (pa, pb) if p is not None]
        return self.add(l, parents)

    def vector_inner(self, parent: int, f: int, mode: str = "dot") -> int:
        """Edge scores.  mode='dot': <h_src, h_dst>; mode='pair_sum':
        s_l[src] + s_r[dst] with f==2 (GAT, expressed as SDDMM of
        [s_l, 1] and [1, s_r] — see DESIGN.md)."""
        l = LayerIR(LayerType.VECTOR_INNER, 0, f_in=f, f_out=1,
                    attrs={"mode": mode})
        return self.add(l, [parent])


# --------------------------------------------------------------------------- #
# Model builders.  `hidden` etc. follow paper Table 5.
# --------------------------------------------------------------------------- #
def build_gcn(g: Graph, hidden: int, n_layers: int = 2, seed: int = 0,
              f_in: Optional[int] = None, n_classes: Optional[int] = None,
              ) -> ModelIR:
    b = _B(g, f"gcn{n_layers}x{hidden}", seed)
    f = f_in or g.feat_dim
    out = n_classes or g.n_classes
    prev = None
    for i in range(n_layers):
        fo = hidden if i < n_layers - 1 else out
        prev = b.aggregate(prev, f, AggOp.SUM)
        prev = b.linear(prev, f, fo)
        if i < n_layers - 1:
            prev = b.activation(prev, fo, Activation.RELU)
        f = fo
    return b.m


def build_sage(g: Graph, hidden: int, n_layers: int = 2, seed: int = 0,
               f_in: Optional[int] = None, n_classes: Optional[int] = None,
               ) -> ModelIR:
    """GraphSAGE-mean: h_i' = ReLU(W_s h_i + W_n mean_j h_j)."""
    b = _B(g, f"sage{n_layers}x{hidden}", seed)
    f = f_in or g.feat_dim
    out = n_classes or g.n_classes
    prev = None
    for i in range(n_layers):
        fo = hidden if i < n_layers - 1 else out
        self_lin = b.linear(prev, f, fo, tag="self")
        agg = b.aggregate(prev, f, AggOp.MEAN)
        neigh_lin = b.linear(agg, f, fo, tag="neigh")
        prev = b.vadd(self_lin, neigh_lin, fo)
        if i < n_layers - 1:
            prev = b.activation(prev, fo, Activation.RELU)
        f = fo
    return b.m


def build_gin(g: Graph, hidden: int, n_layers: int = 5, eps: float = 0.1,
              seed: int = 0, f_in: Optional[int] = None,
              n_classes: Optional[int] = None, batchnorm: bool = True,
              ) -> ModelIR:
    """GIN: h_i' = MLP((1+eps) h_i + sum_j h_j); 2-layer MLP with BN."""
    b = _B(g, f"gin{n_layers}x{hidden}", seed)
    f = f_in or g.feat_dim
    out = n_classes or g.n_classes
    prev = None
    for i in range(n_layers):
        fo = hidden if i < n_layers - 1 else out
        agg = b.aggregate(prev, f, AggOp.SUM)
        # (1+eps)*h_self + sum_neighbors; `prev=None` reads model input.
        mix = b.vadd(agg, prev, f, alpha=1.0, beta=1.0 + eps)
        h = b.linear(mix, f, hidden)
        if batchnorm:
            h = b.batchnorm(h, hidden)
        h = b.activation(h, hidden, Activation.RELU)
        h = b.linear(h, hidden, fo)
        if i < n_layers - 1:
            if batchnorm:
                h = b.batchnorm(h, fo)
            h = b.activation(h, fo, Activation.RELU)
        prev = h
        f = fo
    return b.m


def build_gat(g: Graph, hidden: int, n_layers: int = 2, seed: int = 0,
              f_in: Optional[int] = None, n_classes: Optional[int] = None,
              ) -> ModelIR:
    """Single-head GAT (paper Eq. 4), decomposed per DESIGN.md:
    Linear(W) -> scores Linear(f->2) -> Vector-Inner(pair_sum) ->
    LReLU -> edge softmax -> weighted Aggregate."""
    b = _B(g, f"gat{n_layers}x{hidden}", seed)
    f = f_in or g.feat_dim
    out = n_classes or g.n_classes
    prev = None
    for i in range(n_layers):
        fo = hidden if i < n_layers - 1 else out
        h = b.linear(prev, f, fo, tag="att_proj")
        s = b.linear(h, fo, 2, bias=False, tag="att_scores")
        e = b.vector_inner(s, 2, mode="pair_sum")
        e = b.activation(e, 1, Activation.LRELU, on_edges=True)
        e = b.activation(e, 1, Activation.EDGE_SOFTMAX, on_edges=True)
        h2 = b.aggregate(h, fo, AggOp.SUM, edge_weight_layer=e)
        if i < n_layers - 1:
            h2 = b.activation(h2, fo, Activation.RELU)
        prev = h2
        f = fo
    return b.m


def build_sgc(g: Graph, k: int = 2, seed: int = 0,
              f_in: Optional[int] = None, n_classes: Optional[int] = None,
              ) -> ModelIR:
    b = _B(g, f"sgc_k{k}", seed)
    f = f_in or g.feat_dim
    out = n_classes or g.n_classes
    prev = None
    for _ in range(k):
        prev = b.aggregate(prev, f, AggOp.SUM)
    b.linear(prev, f, out)
    return b.m


def build_graphgym(g: Graph, hidden: int = 256, n_gnn: int = 3, seed: int = 0,
                   f_in: Optional[int] = None, n_classes: Optional[int] = None,
                   ) -> ModelIR:
    """GraphGym-style: 1 pre-MLP, n GNN layers w/ residual+BN, 1 post-MLP."""
    b = _B(g, f"graphgym{n_gnn}x{hidden}", seed)
    f = f_in or g.feat_dim
    out = n_classes or g.n_classes
    h = b.linear(None, f, hidden, tag="pre_mlp")
    h = b.activation(h, hidden, Activation.RELU)
    for _ in range(n_gnn):
        res = h
        a = b.aggregate(h, hidden, AggOp.SUM)
        a = b.linear(a, hidden, hidden)
        a = b.batchnorm(a, hidden)
        a = b.activation(a, hidden, Activation.RELU)
        h = b.vadd(a, res, hidden)
    b.linear(h, hidden, out, tag="post_mlp")
    return b.m


# --------------------------------------------------------------------------- #
BENCHMARKS = {
    "b1": lambda g, s=0: build_gcn(g, 16, 2, seed=s),
    "b2": lambda g, s=0: build_gcn(g, 128, 2, seed=s),
    "b3": lambda g, s=0: build_sage(g, 128, 2, seed=s),
    "b4": lambda g, s=0: build_sage(g, 256, 2, seed=s),
    "b5": lambda g, s=0: build_gin(g, 128, 5, seed=s),
    "b6": lambda g, s=0: build_gat(g, 64, 2, seed=s),
    "b7": lambda g, s=0: build_sgc(g, 2, seed=s),
    "b8": lambda g, s=0: build_graphgym(g, 256, 3, seed=s),
}


def build(name: str, g: Graph, seed: int = 0) -> ModelIR:
    return BENCHMARKS[name](g, seed)
