import os
import sys

# NOTE: do NOT set XLA_FLAGS host-device-count here — smoke tests and
# benches must see 1 device (the dry-run sets 512 in its own process).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Every Engine.compile in the test suite runs the repro.verify static
# checker suite (fresh compiles + livegraph rebinds).
os.environ.setdefault("REPRO_VERIFY", "1")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
