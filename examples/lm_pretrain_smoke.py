"""LM-side smoke: pretrain a reduced assigned-architecture config with
the full substrate (synthetic pipeline, AdamW, checkpoints, resume).

  PYTHONPATH=src python examples/lm_pretrain_smoke.py [arch]

This is the CPU-runnable template of the pod-scale flow that the
multi-pod dry-run compiles at (16,16) and (2,16,16); see
src/repro/launch/train.py for the full driver (crash/resume, int8
gradient compression).
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main as train_main  # noqa: E402

if __name__ == "__main__":
    arch = sys.argv[1] if len(sys.argv) > 1 else "xlstm-125m"
    ckpt = tempfile.mkdtemp(prefix="repro_ckpt_")
    sys.exit(train_main([
        "--arch", arch, "--smoke", "--steps", "60", "--batch", "8",
        "--seq", "128", "--lr", "1e-3", "--log-every", "10",
        "--ckpt-dir", ckpt, "--ckpt-every", "30", "--resume", "auto",
    ]))
