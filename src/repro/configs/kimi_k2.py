"""kimi-k2-1t-a32b [moe] 61L d_model=7168 64H (GQA kv=8, per the assigned
pool line) d_ff(moe)=2048 vocab=163840, MoE 384 experts top-8 + 1 shared,
first layer dense [arXiv:2501.kimi2; unverified]."""
import dataclasses
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b", family="moe", n_layers=61, d_model=7168,
        n_heads=64, n_kv_heads=8, head_dim=112, d_ff=18432, vocab=163840,
        n_experts=384, top_k=8, d_ff_moe=2048, n_shared_experts=1,
        first_k_dense=1, rope_theta=50000.0)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab=256, n_experts=8, top_k=2,
        d_ff_moe=32, first_k_dense=1, attn_chunk=0, remat="none")
