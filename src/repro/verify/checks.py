"""The checker suite — static verification of a GraphAGILE program.

Entry points accept the three forms a program travels in (raw bytes, a
decoded :class:`ExecutionPlan`, a ``.gagi`` bundle / in-memory
:class:`CompiledProgram`) and run every check the available inputs
support — nothing is ever *executed*:

  structure           header/payload agreement, opcode + field ranges,
                      CSI tiling-block accounting, HALT discipline
  def_before_use      every tile read has an earlier (or pre-defined)
                      writer
  use_after_free      no read lands after the residency schedule's
                      last-use position frees the value
  partition_coverage  every (fiber, shard) / (j, k, slice) tile of a
                      layer is produced exactly once
  kernel_legality     per-opcode argument conventions vs tile geometry
                      (coordinates, reduction bounds, nnz, MAC counts,
                      mode selectors, PE range)
  halo_completeness   manifest halo sets == re-derived remote-source
                      sets per device
  resident_budget     independent re-derivation of the device-resident
                      peak-bytes estimate
  liveness_schedule   manifest residency tables == re-derived tables

Violations carry ``instr_lo``/``instr_hi`` so they join against traces
and ``ExecStats.per_layer`` rows.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Set, Tuple

from repro.core.ir import Activation, AggOp, LayerType
from repro.core.isa import (FLAG_ACC, FLAG_LAST, FLAG_LOCK, FLAG_UNLOCK,
                            Buf, Instr, Opcode, Region, disassemble)
from repro.engine.decoder import ExecutionPlan, decode_program

from .hazards import build_hazards, sources_by_shard
from .model import DefUseModel, build_model, tile_slices_from_stats
from .report import VerifyReport

_KNOWN_FLAGS = FLAG_LOCK | FLAG_UNLOCK | FLAG_ACC | FLAG_LAST
_MAX_VIOLATIONS_PER_CHECK = 16


class _Budget:
    """Caps per-check violation volume so a thoroughly corrupted binary
    reports a readable sample, not a million rows."""

    def __init__(self, report: VerifyReport) -> None:
        self.report = report
        self.counts: Dict[str, int] = {}

    def add(self, check: str, message: str, **kw) -> None:
        n = self.counts.get(check, 0)
        self.counts[check] = n + 1
        if n < _MAX_VIOLATIONS_PER_CHECK:
            self.report.add(check, message, **kw)
        elif n == _MAX_VIOLATIONS_PER_CHECK:
            self.report.add(check, "further violations suppressed "
                            f"(cap {_MAX_VIOLATIONS_PER_CHECK})")


def _fibers(f: int, n2: int) -> int:
    return max(1, math.ceil(max(f, 0) / n2))


# --------------------------------------------------------------------------- #
# structure
# --------------------------------------------------------------------------- #
def check_structure(instrs: List[Instr], report: VerifyReport) -> bool:
    """Instruction-stream sanity beyond what decode enforces.  Returns
    False when the stream is too broken for the semantic checks."""
    report.ran("structure")
    v = _Budget(report)
    if not instrs or instrs[-1].op != Opcode.HALT:
        v.add("structure", "program does not end with HALT",
              instr_lo=len(instrs) - 1 if instrs else -1,
              instr_hi=len(instrs) - 1 if instrs else -1)
    halted = False
    for idx, ins in enumerate(instrs):
        if halted:
            v.add("structure",
                  f"{ins.op.name} after HALT is unreachable",
                  instr_lo=idx, instr_hi=idx)
            continue
        if ins.op == Opcode.HALT:
            halted = True
            continue
        if ins.flags & ~_KNOWN_FLAGS:
            v.add("structure",
                  f"{ins.op.name} carries unknown flag bits "
                  f"0x{ins.flags & ~_KNOWN_FLAGS:02X}",
                  instr_lo=idx, instr_hi=idx)
        if ins.op in (Opcode.MEM_RD, Opcode.MEM_WR):
            if ins.args[0] not in tuple(Buf):
                v.add("structure",
                      f"{ins.op.name} names unknown buffer "
                      f"{ins.args[0]}", instr_lo=idx, instr_hi=idx)
            if ins.args[1] not in tuple(Region):
                v.add("structure",
                      f"{ins.op.name} names unknown region "
                      f"{ins.args[1]}", instr_lo=idx, instr_hi=idx)
    return v.counts.get("structure", 0) == 0


# --------------------------------------------------------------------------- #
# def_before_use / use_after_free
# --------------------------------------------------------------------------- #
def check_def_before_use(model: DefUseModel,
                         report: VerifyReport) -> None:
    report.ran("def_before_use")
    v = _Budget(report)
    defined: Set[Tuple] = set(model.predefined)
    for op in model.ops:
        for u in op.uses:
            if u[0] == "g" and not model.graph_tiles_known:
                continue
            if u not in defined:
                v.add("def_before_use",
                      f"{op.kind} tile reads {u} before any definition",
                      layer_id=op.layer_id,
                      instr_lo=op.instr_lo, instr_hi=op.instr_hi)
        defined.update(op.defs)


def derive_last_use(model: DefUseModel) -> Dict[int, int]:
    """Interval-liveness table re-derived from the def/use model: value
    id -> layer step of its last consumer (-1 = input features; the
    sink gets one-past-the-last-layer, the executor's output slice)."""
    last: Dict[int, int] = {}
    for op in model.ops:
        for u in op.uses:
            if u[0] in ("v", "e"):
                lid = int(u[1])
                last[lid] = max(last.get(lid, op.step), op.step)
    if model.plan.layers:
        last[model.plan.layers[-1].layer_id] = len(model.plan.layers)
    return last


def check_use_after_free(model: DefUseModel, residency: dict,
                         report: VerifyReport) -> None:
    """Every read must land at or before the residency schedule's
    last-use position — a later read would hit a freed buffer."""
    report.ran("use_after_free")
    v = _Budget(report)
    sched = {int(k): int(t) for k, t in
             residency.get("last_use", {}).items()}
    for op in model.ops:
        for u in op.uses:
            if u[0] not in ("v", "e"):
                continue
            lid = int(u[1])
            freed_at = sched.get(lid)
            if freed_at is not None and op.step > freed_at:
                v.add("use_after_free",
                      f"{op.kind} tile at layer step {op.step} reads "
                      f"value {lid}, freed after step {freed_at} by the "
                      "residency schedule",
                      layer_id=op.layer_id,
                      instr_lo=op.instr_lo, instr_hi=op.instr_hi)


# --------------------------------------------------------------------------- #
# partition_coverage
# --------------------------------------------------------------------------- #
def check_partition_coverage(model: DefUseModel, report: VerifyReport,
                             ) -> None:
    report.ran("partition_coverage")
    v = _Budget(report)
    n2, nb = model.n2, model.nb
    # Graph-tile slice universe, from the predefined set.
    eslices: Dict[Tuple[int, int], int] = {}
    for key in model.predefined:
        if key[0] == "g":
            _, j, k, s = key
            eslices[(j, k)] = max(eslices.get((j, k), 0), s + 1)
    for lp in model.plan.layers:
        lt = lp.layer_type
        edge_layer = (lt == LayerType.VECTOR_INNER or lp.on_edges)
        if edge_layer:
            if not model.graph_tiles_known:
                continue
            expected = {(j, k, s) for (j, k), n in eslices.items()
                        for s in range(n)}
            got: Dict[Tuple[int, int, int], int] = {}
            for tp in lp.tiles:
                c = (tp.out_j, tp.tile_k, tp.slice_id)
                got[c] = got.get(c, 0) + 1
            label = "(j, k, slice)"
        else:
            nf = _fibers(lp.f_out if lt == LayerType.LINEAR else lp.f_in,
                         n2)
            expected = {(i, j) for i in range(nf) for j in range(nb)}
            got = {}
            for tp in lp.tiles:
                c = (tp.out_i, tp.out_j)
                got[c] = got.get(c, 0) + 1
            label = "(fiber, shard)"
        for c in sorted(expected - set(got)):
            v.add("partition_coverage",
                  f"{label} tile {c} is never produced",
                  layer_id=lp.layer_id,
                  instr_lo=lp.instr_lo, instr_hi=lp.instr_hi)
        for c, n in sorted(got.items()):
            if c not in expected:
                v.add("partition_coverage",
                      f"unexpected {label} tile {c} outside the "
                      "partition grid", layer_id=lp.layer_id,
                      instr_lo=lp.instr_lo, instr_hi=lp.instr_hi)
            elif n > 1:
                v.add("partition_coverage",
                      f"{label} tile {c} is produced {n} times",
                      layer_id=lp.layer_id,
                      instr_lo=lp.instr_lo, instr_hi=lp.instr_hi)


# --------------------------------------------------------------------------- #
# kernel_legality
# --------------------------------------------------------------------------- #
_ALLOWED_COMPUTE = {
    LayerType.AGGREGATE: {Opcode.SPDMM},
    LayerType.LINEAR: {Opcode.GEMM},
    LayerType.VECTOR_INNER: {Opcode.SDDMM},
    LayerType.VECTOR_ADD: {Opcode.VADD},
    LayerType.ACTIVATION: {Opcode.ACT},
    LayerType.BATCHNORM: {Opcode.AFFINE, Opcode.ACT},
}


def check_kernel_legality(model: DefUseModel, report: VerifyReport,
                          n_pes: Optional[int] = None, pgraph=None,
                          rebound: bool = False,
                          remap: Optional[dict] = None) -> None:
    """Per-opcode argument conventions vs the tile geometry.

    ``rebound`` (livegraph): tile *contents* were patched after codegen,
    so nnz operands in the binary are checked against slice capacity
    (n1 x width) instead of exact equality.

    ``remap`` (sparsity-adaptive remapping): the manifest ``remap``
    record.  When present, an AGGREGATE tile the record marks ``gemm``
    may be encoded as a dense-aggregate GEMM — SUM/MEAN reductions
    only, MAC count n1*n1*n2 (the densified block), distinguishing it
    from a LINEAR GEMM's n1*n2*n2 — and a tile marked ``skip`` must
    carry no compute at all and hold zero live edges.  Any encoding
    that disagrees with the record fails in BOTH directions: a GEMM
    whose tile the record calls spdmm/skip/absent, and an SPDMM whose
    tile the record calls gemm in a densifiable layer."""
    report.ran("kernel_legality")
    v = _Budget(report)
    rec_tiles = (remap or {}).get("tiles", {})
    n1, n2, nb = model.n1, model.n2, model.nb
    for lp in model.plan.layers:
        lt = lp.layer_type
        fi = _fibers(lp.f_in, n2)
        fo = _fibers(lp.f_out, n2)
        # CSI mode selector ranges.
        if lt == LayerType.AGGREGATE and lp.mode not in tuple(AggOp):
            v.add("kernel_legality",
                  f"CSI announces AggOp {lp.mode}, outside the "
                  "AggOp range", layer_id=lp.layer_id,
                  instr_lo=lp.instr_lo, instr_hi=lp.instr_lo)
        if lt == LayerType.ACTIVATION and \
                lp.mode not in tuple(Activation):
            v.add("kernel_legality",
                  f"CSI announces Activation {lp.mode}, outside the "
                  "Activation range", layer_id=lp.layer_id,
                  instr_lo=lp.instr_lo, instr_hi=lp.instr_lo)
        if lt == LayerType.VECTOR_INNER and lp.mode not in (0, 1):
            v.add("kernel_legality",
                  f"CSI announces vector-inner mode {lp.mode} "
                  "(expected 0=dot or 1=pair-sum)",
                  layer_id=lp.layer_id,
                  instr_lo=lp.instr_lo, instr_hi=lp.instr_lo)
        allowed = _ALLOWED_COMPUTE.get(lt, set())
        for tp in lp.tiles:
            lo, hi = tp.instr_lo, tp.instr_hi

            def bad(msg: str) -> None:
                v.add("kernel_legality", msg, layer_id=lp.layer_id,
                      instr_lo=lo, instr_hi=hi)

            if n_pes is not None and tp.pe >= n_pes:
                bad(f"tile assigned to PE {tp.pe} but the overlay has "
                    f"{n_pes} PEs")
            if tp.out_j >= nb:
                bad(f"destination row block {tp.out_j} outside the "
                    f"{nb}-block grid")
            for ins in tp.compute:
                if lt == LayerType.AGGREGATE and ins.op == Opcode.GEMM:
                    j, k, i, _packed = ins.args
                    entry = rec_tiles.get(f"{j}:{k}")
                    mode = entry.get("mode") if entry else None
                    if remap is None:
                        bad("GEMM inside an AGGREGATE layer with no "
                            "remap record (expects SPDMM)")
                    elif mode != "gemm":
                        bad(f"GEMM encodes aggregate tile ({j}, {k}) "
                            "but the remap record marks it "
                            f"{mode or 'unmapped'}")
                    if lp.mode in (int(AggOp.SUM), int(AggOp.MEAN)):
                        if ins.arg4 != n1 * n1 * n2:
                            bad("dense-aggregate GEMM announces "
                                f"{ins.arg4} MACs, the densified tile "
                                f"implies {n1 * n1 * n2}")
                    else:
                        bad("dense-aggregate GEMM under a non-linear "
                            f"reduction (AggOp {lp.mode}); only "
                            "SUM/MEAN may densify")
                    if (j, i) != (tp.out_j, tp.out_i):
                        bad(f"GEMM targets (j={j}, i={i}) but the "
                            f"tiling block writes (j={tp.out_j}, "
                            f"i={tp.out_i})")
                    if k >= nb:
                        bad(f"GEMM source block {k} outside the "
                            f"{nb}-block grid")
                    if i >= fi:
                        bad(f"GEMM input fiber {i} outside the "
                            f"{fi}-fiber grid")
                    continue
                if ins.op not in allowed:
                    bad(f"{ins.op.name} inside a {lt.name} layer "
                        "(expects "
                        f"{'/'.join(o.name for o in sorted(allowed))})")
                    continue
                if ins.op == Opcode.GEMM:
                    j, k, i, _ = ins.args
                    if (j, i) != (tp.out_j, tp.out_i):
                        bad(f"GEMM targets (j={j}, i={i}) but the "
                            f"tiling block writes (j={tp.out_j}, "
                            f"i={tp.out_i})")
                    if k >= fi:
                        bad(f"GEMM reduction fiber {k} outside the "
                            f"{fi}-fiber input grid")
                    if i >= fo:
                        bad(f"GEMM output fiber {i} outside the "
                            f"{fo}-fiber output grid")
                    if ins.arg4 != n1 * n2 * n2:
                        bad(f"GEMM announces {ins.arg4} MACs, tile "
                            f"geometry implies {n1 * n2 * n2}")
                elif ins.op == Opcode.SPDMM:
                    j, k, i, packed = ins.args
                    s = packed >> 1
                    if (j, i) != (tp.out_j, tp.out_i):
                        bad(f"SPDMM targets (j={j}, i={i}) but the "
                            f"tiling block writes (j={tp.out_j}, "
                            f"i={tp.out_i})")
                    if k >= nb:
                        bad(f"SPDMM source block {k} outside the "
                            f"{nb}-block grid")
                    if i >= fi:
                        bad(f"SPDMM input fiber {i} outside the "
                            f"{fi}-fiber grid")
                    entry = rec_tiles.get(f"{j}:{k}")
                    emode = entry.get("mode") if entry else None
                    if emode == "gemm" and lp.mode in (
                            int(AggOp.SUM), int(AggOp.MEAN)):
                        bad(f"SPDMM encodes aggregate tile ({j}, {k}) "
                            "but the remap record marks it gemm")
                    elif emode == "skip":
                        bad(f"tile ({j}, {k}) still carries compute "
                            "but the remap record elides it as "
                            "skip-empty")
                    _check_nnz(ins, j, k, s, pgraph, rebound, n1, bad)
                elif ins.op == Opcode.SDDMM:
                    j, k, i, s = ins.args
                    if (j, k, s) != (tp.out_j, tp.tile_k, tp.slice_id):
                        bad(f"SDDMM addresses tile ({j}, {k}, {s}) but "
                            "the tiling block writes "
                            f"({tp.out_j}, {tp.tile_k}, {tp.slice_id})")
                    if i >= fi:
                        bad(f"SDDMM fiber {i} outside the {fi}-fiber "
                            "grid")
                    _check_nnz(ins, j, k, s, pgraph, rebound, n1, bad)
                elif ins.op == Opcode.VADD:
                    i, j = ins.args[0], ins.args[1]
                    if (i, j) != (tp.out_i, tp.out_j):
                        bad(f"VADD targets (i={i}, j={j}) but the "
                            f"tiling block writes (i={tp.out_i}, "
                            f"j={tp.out_j})")
                elif ins.op in (Opcode.ACT, Opcode.AFFINE):
                    if ins.args[0] != lp.layer_id:
                        bad(f"{ins.op.name} names layer {ins.args[0]} "
                            f"inside layer {lp.layer_id}'s block")
                    if ins.op == Opcode.ACT and ins.act_en \
                            and ins.act not in tuple(Activation):
                        bad(f"ACT selects activation {ins.act}, "
                            "outside the Activation range")
    # Skip-elided tiles must actually be empty — a record that elides
    # a tile with live edges would silently drop messages.
    if rec_tiles and pgraph is not None:
        for jk, entry in sorted(rec_tiles.items()):
            if entry.get("mode") != "skip":
                continue
            j, k = (int(x) for x in jk.split(":"))
            nnz = sum(int(t.nnz) for t in pgraph.tiles.get((j, k), []))
            if nnz:
                v.add("kernel_legality",
                      f"remap record elides tile ({j}, {k}) as "
                      f"skip-empty but its ELL slices hold {nnz} "
                      "live edges")


def _check_nnz(ins, j: int, k: int, s: int, pgraph, rebound: bool,
               n1: int, bad) -> None:
    if pgraph is None:
        return
    slices = pgraph.tiles.get((j, k), [])
    if s >= len(slices):
        bad(f"{ins.op.name} addresses ELL slice {s} of tile "
            f"({j}, {k}) but only {len(slices)} slice(s) exist")
        return
    tile = slices[s]
    if rebound:
        if ins.arg4 == 0 or tile.nnz == 0:
            # A rebind can empty a slice (live tile drained by a
            # delta) without re-encoding arg4; staging reads the ELL
            # planes by shape, so the operand is advisory here.
            return
        cap = n1 * tile.width
        if ins.arg4 > cap:
            bad(f"{ins.op.name} announces {ins.arg4} nnz for tile "
                f"({j}, {k}, {s}) — over the {cap}-slot slice "
                "capacity even after rebind")
    elif ins.arg4 != tile.nnz:
        bad(f"{ins.op.name} announces {ins.arg4} nnz for tile "
            f"({j}, {k}, {s}) but the ELL slice holds {tile.nnz}")


# --------------------------------------------------------------------------- #
# liveness_schedule / halo_completeness
# --------------------------------------------------------------------------- #
def derive_residency_tables(model: DefUseModel) -> dict:
    """Residency schedule re-derived from the def/use model (same
    semantics as ``repro.core.passes.schedule.residency_schedule``, but
    computed from decoded instructions — the verifier's independent
    path)."""
    from repro.core.passes.schedule import _order_shards
    layers: Dict[str, dict] = {}
    shard_sources = sources_by_shard(model)
    for lp in model.plan.layers:
        sources = shard_sources[lp.layer_id]
        layers[str(lp.layer_id)] = {
            "shard_order": [int(j) for j in _order_shards(sources)],
            "sources": {str(j): sorted(int(k) for k in ks)
                        for j, ks in sources.items()},
        }
    return {
        "last_use": {str(k): int(t)
                     for k, t in sorted(derive_last_use(model).items())},
        "layers": layers,
    }


def check_liveness_schedule(model: DefUseModel, residency: dict,
                            report: VerifyReport,
                            remapped: bool = False) -> None:
    """``remapped``: skip-elided tiles removed reads *after* the
    residency schedule was built, so the binary's tables may be a
    conservative SUBSET of the manifest's (earlier last_use, fewer
    gather sources) — the manifest then over-retains, which is safe.
    The reverse direction (binary reads more than the manifest
    schedules) still fails."""
    report.ran("liveness_schedule")
    v = _Budget(report)
    derived = derive_residency_tables(model)
    man_last = {int(k): int(t) for k, t in
                residency.get("last_use", {}).items()}
    der_last = {int(k): int(t) for k, t in derived["last_use"].items()}
    for lid in sorted(set(man_last) | set(der_last)):
        a, b = man_last.get(lid), der_last.get(lid)
        if a == b:
            continue
        if remapped and a is not None and (b is None or b <= a):
            continue
        v.add("liveness_schedule",
              f"last_use[{lid}]: manifest says step {a}, binary "
              f"implies step {b}", layer_id=lid)
    man_layers = residency.get("layers", {})
    for lp in model.plan.layers:
        key = str(lp.layer_id)
        mine = derived["layers"][key]
        theirs = man_layers.get(key)
        if theirs is None:
            v.add("liveness_schedule",
                  "manifest residency has no entry for this layer",
                  layer_id=lp.layer_id, instr_lo=lp.instr_lo,
                  instr_hi=lp.instr_hi)
            continue
        theirs_src = theirs.get("sources") or {}
        if theirs_src != mine["sources"]:
            subset = remapped and all(
                set(ks) <= {int(x) for x in theirs_src.get(jstr, [])}
                for jstr, ks in mine["sources"].items())
            if not subset:
                v.add("liveness_schedule",
                      "manifest per-shard source lists disagree with "
                      "the binary's gather set", layer_id=lp.layer_id,
                      instr_lo=lp.instr_lo, instr_hi=lp.instr_hi)
        if sorted(theirs.get("shard_order", [])) != \
                sorted(mine["shard_order"]):
            v.add("liveness_schedule",
                  "manifest shard_order is not a permutation of the "
                  "binary's destination shards", layer_id=lp.layer_id,
                  instr_lo=lp.instr_lo, instr_hi=lp.instr_hi)


def check_halo_completeness(model: DefUseModel, placement: dict,
                            report: VerifyReport,
                            remapped: bool = False) -> None:
    """Every remote source block a device's shards gather from must be
    in that device's manifest halo set (and nothing else).  When
    ``remapped``, skip elision may have removed gathers after the
    placement was scheduled, so an over-full halo set (extra blocks)
    is tolerated; a missing block still fails."""
    report.ran("halo_completeness")
    v = _Budget(report)
    assignment = [int(a) for a in placement.get("assignment", [])]
    n_devices = int(placement.get("n_devices", 0))
    if len(assignment) < model.nb or n_devices <= 0:
        v.add("halo_completeness",
              f"placement assigns {len(assignment)} row blocks but the "
              f"program addresses {model.nb}")
        return
    owned: List[Set[int]] = [set() for _ in range(n_devices)]
    for j, d in enumerate(assignment):
        owned[d].add(j)
    shard_sources = sources_by_shard(model)
    man_layers = placement.get("layers", {})
    for lp in model.plan.layers:
        rec = man_layers.get(str(lp.layer_id))
        if rec is None:
            v.add("halo_completeness",
                  "placement has no entry for this layer",
                  layer_id=lp.layer_id, instr_lo=lp.instr_lo,
                  instr_hi=lp.instr_hi)
            continue
        need: List[Set[int]] = [set() for _ in range(n_devices)]
        for j, ks in shard_sources[lp.layer_id].items():
            need[assignment[j]].update(ks)
        for d in range(n_devices):
            halo = set(int(k) for k in rec.get("halo", {})
                       .get(str(d), []))
            required = need[d] - owned[d]
            missing = required - halo
            extra = halo - required
            if missing:
                v.add("halo_completeness",
                      f"device {d} gathers remote source blocks "
                      f"{sorted(missing)} absent from its halo set",
                      layer_id=lp.layer_id, instr_lo=lp.instr_lo,
                      instr_hi=lp.instr_hi)
            if extra and not remapped:
                v.add("halo_completeness",
                      f"device {d}'s halo set lists blocks "
                      f"{sorted(extra)} no shard of it reads",
                      layer_id=lp.layer_id, instr_lo=lp.instr_lo,
                      instr_hi=lp.instr_hi)


# --------------------------------------------------------------------------- #
# resident_budget
# --------------------------------------------------------------------------- #
def rederive_device_peak_bytes(model: DefUseModel, pgraph,
                               weights: Dict) -> int:
    """Liveness-aware peak device bytes of a device-resident pass,
    re-derived from CSI fields + the def/use liveness — independent of
    ``BinaryExecutor._live_profile`` (numpy-free accounting)."""
    import numpy as np
    n1, n2, nb = model.n1, model.n2, model.nb
    static = (pgraph.tile_bytes()
              + sum(int(np.asarray(w).size)
                    * np.asarray(w).dtype.itemsize
                    for w in weights.values())
              + pgraph.inv_in_degree.size
              * pgraph.inv_in_degree.dtype.itemsize)
    layers = model.plan.layers
    if not layers:
        return static
    fin_pad0 = _fibers(layers[0].f_in, n2) * n2
    x_bytes = nb * n1 * fin_pad0 * 4
    last = derive_last_use(model)
    sizes: Dict[int, int] = {}
    births: Dict[int, int] = {}
    for t, lp in enumerate(layers):
        births[lp.layer_id] = t
        if model.layer_kind[lp.layer_id] == "e":
            sizes[lp.layer_id] = (pgraph.n_edges + 1) * 4
        else:
            f = (lp.f_out if lp.layer_type == LayerType.LINEAR
                 else lp.f_in)
            sizes[lp.layer_id] = nb * n1 * _fibers(f, n2) * n2 * 4
    n = len(layers)
    peak_live = max(
        sum(sz for lid, sz in sizes.items()
            if births[lid] <= t <= max(last.get(lid, n), births[lid]))
        for t in range(n))
    return static + x_bytes + peak_live


def check_resident_budget(model: DefUseModel, prog,
                          report: VerifyReport) -> None:
    """The executor's budget gate prices runs with
    ``estimate_device_peak_bytes``; this check re-derives the same peak
    from the binary alone and flags any drift between the two."""
    report.ran("resident_budget")
    from repro.engine.executor import BinaryExecutor
    mine = rederive_device_peak_bytes(model, prog.pgraph, prog.weights)
    theirs = BinaryExecutor().estimate_device_peak_bytes(prog)
    report.stats["device_peak_bytes"] = int(mine)
    if mine != theirs:
        report.add(
            "resident_budget",
            f"re-derived device-resident peak is {mine} bytes but the "
            f"executor's estimate is {theirs} — the budget gate and "
            f"the binary disagree by {abs(mine - theirs)} bytes")


# --------------------------------------------------------------------------- #
# Entry points
# --------------------------------------------------------------------------- #
def verify_plan(plan: ExecutionPlan, instrs: List[Instr],
                lmeta: Optional[dict], geometry: Optional[dict],
                *, pgraph=None, weights=None, prog=None,
                residency: Optional[dict] = None,
                placement: Optional[dict] = None,
                n_pes: Optional[int] = None, rebound: bool = False,
                tile_slices=None, remap: Optional[dict] = None,
                label: str = "") -> VerifyReport:
    """Run every check the supplied inputs support."""
    report = VerifyReport(program=label)
    report.stats.update(n_instrs=len(instrs), n_layers=plan.n_layers,
                        n_tiles=sum(len(lp.tiles)
                                    for lp in plan.layers))
    check_structure(instrs, report)
    if lmeta is None or geometry is None:
        reason = "needs a manifest (layer table + geometry)"
        for c in ("def_before_use", "use_after_free",
                  "partition_coverage", "kernel_legality",
                  "liveness_schedule"):
            report.skip(c, reason)
        report.skip("halo_completeness", reason)
        report.skip("resident_budget", reason)
        return report
    model = build_model(plan, lmeta, geometry, pgraph=pgraph,
                        tile_slices=tile_slices)
    hz = build_hazards(model, lmeta)
    report.stats.update(n_values=len(model.predefined),
                        hazard_edges=hz.counts)
    check_def_before_use(model, report)
    check_partition_coverage(model, report)
    check_kernel_legality(model, report, n_pes=n_pes, pgraph=pgraph,
                          rebound=rebound, remap=remap)
    if residency is not None:
        check_use_after_free(model, residency, report)
        check_liveness_schedule(model, residency, report,
                                remapped=remap is not None)
    else:
        reason = "no residency schedule supplied"
        report.skip("use_after_free", reason)
        report.skip("liveness_schedule", reason)
    if placement is not None:
        check_halo_completeness(model, placement, report,
                                remapped=remap is not None)
    else:
        report.skip("halo_completeness",
                    "program carries no placement schedule")
    if prog is not None and pgraph is not None:
        check_resident_budget(model, prog, report)
    else:
        report.skip("resident_budget",
                    "needs tiles + weights (full program)")
    return report


def verify_binary(binary: bytes, manifest: Optional[dict] = None,
                  pgraph=None, label: str = "") -> VerifyReport:
    """Verify raw binary bytes (+ optional manifest / tiles).  Decode
    failures become ``structure`` violations, never exceptions."""
    report = VerifyReport(program=label or "<binary>")
    try:
        instrs = disassemble(binary)
        plan = decode_program(instrs)
    except ValueError as e:
        report.ran("structure")
        report.add("structure", str(e))
        for c in ("def_before_use", "use_after_free",
                  "partition_coverage", "kernel_legality",
                  "halo_completeness", "resident_budget",
                  "liveness_schedule"):
            report.skip(c, "binary failed to decode")
        return report
    lmeta = manifest.get("layers") if manifest else None
    geometry = manifest.get("geometry") if manifest else None
    tile_slices = None
    if pgraph is None and manifest and "tile_stats" in manifest:
        tile_slices = tile_slices_from_stats(manifest["tile_stats"])
    return verify_plan(
        plan, instrs, lmeta, geometry, pgraph=pgraph,
        residency=manifest.get("residency") if manifest else None,
        placement=manifest.get("placement") if manifest else None,
        n_pes=(int(geometry.get("n_pes", 0)) or None)
        if geometry else None,
        rebound=bool(manifest and "graph_version" in manifest),
        tile_slices=tile_slices,
        remap=manifest.get("remap") if manifest else None,
        label=report.program)


def verify_program(prog, label: str = "") -> VerifyReport:
    """Verify a :class:`CompiledProgram` — the full suite."""
    name = label or f"{prog.model_name}::{prog.graph_name}"
    report = VerifyReport(program=name)
    try:
        instrs = disassemble(prog.binary)
        plan = decode_program(instrs)
    except ValueError as e:
        report.ran("structure")
        report.add("structure", str(e))
        for c in ("def_before_use", "use_after_free",
                  "partition_coverage", "kernel_legality",
                  "halo_completeness", "resident_budget",
                  "liveness_schedule"):
            report.skip(c, "binary failed to decode")
        return report
    man = prog.manifest
    geometry = man.get("geometry")
    return verify_plan(
        plan, instrs, man.get("layers"), geometry,
        pgraph=prog.pgraph, weights=prog.weights, prog=prog,
        residency=man.get("residency"),
        placement=man.get("placement"),
        n_pes=(int(geometry.get("n_pes", 0)) or None)
        if geometry else None,
        rebound="graph_version" in man, remap=man.get("remap"),
        label=name)


def verify_gagi(path: str) -> VerifyReport:
    """Load a ``.gagi`` bundle and verify it."""
    from repro.engine.program import CompiledProgram
    import os
    prog = CompiledProgram.load(path)
    return verify_program(prog, label=os.path.basename(path))


def verify(obj, **kw) -> VerifyReport:
    """Polymorphic front door: bytes, ``.gagi`` path, ExecutionPlan, or
    CompiledProgram."""
    if isinstance(obj, bytes):
        return verify_binary(obj, **kw)
    if isinstance(obj, str):
        return verify_gagi(obj)
    if isinstance(obj, ExecutionPlan):
        instrs: List[Instr] = []
        return verify_plan(obj, instrs, kw.get("lmeta"),
                           kw.get("geometry"),
                           label=kw.get("label", "<plan>"))
    return verify_program(obj, label=kw.get("label", ""))
