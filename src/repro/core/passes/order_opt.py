"""Compiler Step 1 — computation order optimization (paper §6.3, Alg. 5).

For every adjacent {Aggregate, Linear} pair where the aggregation operator is
linear (Definition 1) and the exchange lowers total complexity (Theorem 2),
exchange the two layers.  Applied to a fixpoint.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

from ..ir import LayerType, ModelIR


@dataclasses.dataclass
class OrderOptReport:
    exchanges: List[Tuple[int, int]]
    complexity_before: float
    complexity_after: float

    @property
    def reduction(self) -> float:
        if self.complexity_before == 0:
            return 0.0
        return 1.0 - self.complexity_after / self.complexity_before


def _try_pairs(m: ModelIR) -> List[Tuple[int, int]]:
    """One sweep of Algorithm 5; returns pairs exchanged."""
    done: List[Tuple[int, int]] = []
    for lid in list(m.topo_order()):
        if lid not in m.layers:
            continue
        l = m.layers[lid]
        # Check: layer l has only one child m_.
        if len(l.child_ids) != 1:
            continue
        mid = l.child_ids[0]
        ml = m.layers[mid]
        # Check: layer m_ has only one parent (l).
        if len(ml.parent_ids) != 1:
            continue
        # Check: {Aggregate, Linear} pair (either order).
        pair = {l.layer_type, ml.layer_type}
        if pair != {LayerType.AGGREGATE, LayerType.LINEAR}:
            continue
        agg = l if l.layer_type == LayerType.AGGREGATE else ml
        lin = ml if agg is l else l
        # Check: aggregation operator is linear (Definition 1).
        if agg.agg_op is None or not agg.agg_op.is_linear:
            continue
        # Dynamic edge weights (GAT) give the Aggregate a second parent, so
        # they are already excluded by the single-parent check; be explicit:
        if "edge_weight_layer" in agg.attrs:
            continue
        # Fused epilogues pin the order (act(agg(x))·W != act(agg(x·W))).
        if "fused_act" in l.attrs:
            continue
        # Check: exchanging reduces complexity (Theorem 2).
        before = l.complexity() + ml.complexity()
        f1, f2 = lin.f_in, lin.f_out
        e, v = agg.n_edges, agg.n_vertices
        if l is agg:  # Aggregate->Linear, candidate Linear->Aggregate
            after = 2.0 * f1 * f2 * v + 2.0 * f2 * e
        else:         # Linear->Aggregate, candidate Aggregate->Linear
            after = 2.0 * f1 * e + 2.0 * f1 * f2 * v
        if after >= before:
            continue
        m.exchange(lid, mid)
        done.append((lid, mid))
    return done


def run(m: ModelIR, enabled: bool = True) -> OrderOptReport:
    before = m.total_complexity()
    exchanges: List[Tuple[int, int]] = []
    if enabled:
        while True:
            got = _try_pairs(m)
            if not got:
                break
            exchanges.extend(got)
    return OrderOptReport(exchanges, before, m.total_complexity())
